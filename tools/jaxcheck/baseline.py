"""Baseline file: the set of findings the repo has accepted, each with
a mandatory human-written reason.

Format (one finding per line, tab-separated)::

    JX001<TAB>src/repro/serving/router.py::AdaptiveReplanner.replan<TAB>best = int(best_dev)<TAB>the ONE deliberate sync per replan

Keys are ``(rule, path, qualname, normalized snippet)`` — no line
numbers, so unrelated edits above a finding never invalidate the
baseline. Semantics are a **multiset**: two identical snippets in the
same function need two baseline lines. A reasonless line is a parse
error (exit 2), not a warning — the baseline is documentation, not a
mute button.
"""
from __future__ import annotations

from collections import Counter
from pathlib import Path

from tools.jaxcheck.base import Finding

_SEP = "\t"


class BaselineError(ValueError):
    """Malformed baseline file (wrong arity, unknown rule, no reason)."""


def parse_baseline(path: Path) -> Counter:
    """-> Counter of finding keys accepted by the baseline."""
    accepted: Counter = Counter()
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.rstrip("\n")
        if not line.strip() or line.lstrip().startswith("#"):
            continue
        parts = line.split(_SEP)
        if len(parts) != 4:
            raise BaselineError(
                f"{path}:{i}: expected 4 tab-separated fields "
                f"(rule, path::qualname, snippet, reason), got "
                f"{len(parts)}"
            )
        rule, where, snippet, reason = (p.strip() for p in parts)
        if not (rule.startswith("JX") and len(rule) == 5):
            raise BaselineError(f"{path}:{i}: bad rule code {rule!r}")
        if "::" not in where:
            raise BaselineError(
                f"{path}:{i}: location must be `path::qualname` "
                f"(qualname may be empty), got {where!r}"
            )
        if not reason:
            raise BaselineError(
                f"{path}:{i}: baseline entries require a reason — "
                f"explain why this finding is accepted"
            )
        fpath, qualname = where.split("::", 1)
        accepted[(rule, fpath, qualname, snippet)] += 1
    return accepted


def diff_against_baseline(
    findings: list[Finding], accepted: Counter
) -> tuple[list[Finding], list[tuple]]:
    """-> (new findings not covered by the baseline, stale baseline
    keys with no matching finding). Multiset semantics throughout."""
    budget = Counter(accepted)
    new: list[Finding] = []
    for f in findings:
        if budget[f.key] > 0:
            budget[f.key] -= 1
        else:
            new.append(f)
    stale = sorted(
        key for key, count in budget.items() for _ in range(count)
    )
    return new, stale


def format_baseline_line(f: Finding, reason: str) -> str:
    return _SEP.join(
        (f.rule, f"{f.path}::{f.qualname}", f.snippet, reason)
    )
