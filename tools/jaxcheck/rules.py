"""Rule implementations JX000–JX005.

Each rule is a function ``(contexts, registry) -> list[Finding]`` over
the parsed :class:`~tools.jaxcheck.analyzer.FileContext` set plus the
cross-file jit registry (rule JX002 needs call sites in one file to see
``static_argnames`` declared in another). Suppression filtering happens
in the orchestrator, not here.
"""
from __future__ import annotations

import ast
import dataclasses
import re

from tools.jaxcheck import config
from tools.jaxcheck.analyzer import (
    JAX_HOST_FNS,
    NUMPY_MATERIALIZERS,
    SCALAR_COERCIONS,
    FileContext,
    FunctionInfo,
    TaintEnv,
    dotted_name,
    last_segment,
)
from tools.jaxcheck.base import Finding

DIRECTIVE_ATTEMPT_RE = re.compile(r"#\s*jaxcheck\s*:")

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# mutating methods that leak state when called on a closed-over object
# from traced code
_MUTATORS = frozenset(
    {
        "append",
        "extend",
        "insert",
        "add",
        "update",
        "setdefault",
        "pop",
        "popitem",
        "remove",
        "discard",
        "clear",
        "sort",
    }
)

_NONDET_EXACT = frozenset(
    {
        "time.time",
        "time.time_ns",
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "datetime.datetime.now",
        "datetime.datetime.utcnow",
        "datetime.date.today",
        "uuid.uuid4",
        "uuid.uuid1",
        "os.urandom",
    }
)
_NONDET_PREFIXES = ("random.", "numpy.random.", "secrets.")

_UNHASHABLE_BUILTINS = frozenset({"list", "dict", "set", "bytearray"})


def _own_nodes(fn_node: ast.AST):
    """Walk a function's body without descending into nested functions
    (those are analyzed in their own right)."""
    if isinstance(fn_node, ast.Lambda):
        roots = [fn_node.body]
    else:
        roots = list(fn_node.body)
    stack = list(roots)
    while stack:
        n = stack.pop()
        yield n
        for c in ast.iter_child_nodes(n):
            if isinstance(c, _FUNC_NODES):
                continue
            stack.append(c)


def _parent_map(tree: ast.AST) -> dict[int, ast.AST]:
    return {
        id(child): node
        for node in ast.walk(tree)
        for child in ast.iter_child_nodes(node)
    }


# ---------------------------------------------------------------------------
# JX000 — malformed suppression directives.
# ---------------------------------------------------------------------------


def check_jx000(
    contexts: list[FileContext], registry: dict
) -> list[Finding]:
    out: list[Finding] = []
    for ctx in contexts:
        for line_no, (codes, ok, reason) in sorted(ctx.suppress.items()):
            if ok and reason:
                continue
            missing = "an `ok`" if not ok else "a reason"
            out.append(
                Finding(
                    rule="JX000",
                    path=ctx.rel,
                    line=line_no,
                    qualname="",
                    message=(
                        f"suppression for {', '.join(sorted(codes))} is "
                        f"missing {missing} — reasons are mandatory"
                    ),
                    snippet=ctx.lines[line_no - 1].strip(),
                )
            )
        # directive attempts the grammar did not recognize at all
        for i, line in enumerate(ctx.lines, start=1):
            if i in ctx.suppress or "jaxcheck" not in line:
                continue
            hash_pos = line.find("#")
            if hash_pos < 0:
                continue
            if DIRECTIVE_ATTEMPT_RE.search(line, hash_pos):
                out.append(
                    Finding(
                        rule="JX000",
                        path=ctx.rel,
                        line=i,
                        qualname="",
                        message=(
                            "unparseable jaxcheck directive (expected "
                            "`# jaxcheck: JX00N ok <reason>`)"
                        ),
                        snippet=line.strip(),
                    )
                )
    return out


# ---------------------------------------------------------------------------
# JX001 — host sync in a device hot path.
# ---------------------------------------------------------------------------


class _SyncChecker:
    def __init__(self, ctx: FileContext, info: FunctionInfo):
        self.ctx = ctx
        self.info = info
        self.env = TaintEnv(ctx, info)
        self.findings: list[Finding] = []
        self.loop_depth = 0

    def run(self) -> list[Finding]:
        node = self.info.node
        if isinstance(node, ast.Lambda):
            self._check_expr(node.body)
        else:
            self._block(node.body)
        return self.findings

    # -- statements ---------------------------------------------------

    def _block(self, stmts) -> None:
        for st in stmts:
            self._stmt(st)

    def _stmt(self, st: ast.stmt) -> None:
        if isinstance(st, _FUNC_NODES[:2]):
            # nested defs get their own pass when hot; record the name
            # as a host-bound local
            self.env.tainted.discard(st.name)
            return
        if isinstance(st, ast.ClassDef):
            return
        if isinstance(st, ast.Assign):
            self._check_expr(st.value)
            t = self.env.taint(st.value)
            for tgt in st.targets:
                self.env.assign(tgt, t)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._check_expr(st.value)
                self.env.assign(st.target, self.env.taint(st.value))
        elif isinstance(st, ast.AugAssign):
            self._check_expr(st.value)
            if self.env.taint(st.value):
                self.env.assign(st.target, True)
        elif isinstance(st, (ast.Expr, ast.Return)):
            if st.value is not None:
                self._check_expr(st.value)
        elif isinstance(st, ast.If):
            self._truthiness(st.test)
            self._check_expr(st.test)
            self._block(st.body)
            self._block(st.orelse)
        elif isinstance(st, ast.While):
            self._truthiness(st.test)
            self._check_expr(st.test)
            self.loop_depth += 1
            self._block(st.body)
            self.loop_depth -= 1
            self._block(st.orelse)
        elif isinstance(st, ast.For):
            self._check_expr(st.iter)
            if self.env.taint(st.iter):
                self._emit(
                    st.iter,
                    "iterating a device array — one implicit host sync "
                    "per element",
                )
                self.env.assign(st.target, True)
            else:
                self.env.assign(st.target, False)
            self.loop_depth += 1
            self._block(st.body)
            self.loop_depth -= 1
            self._block(st.orelse)
        elif isinstance(st, ast.With):
            for item in st.items:
                self._check_expr(item.context_expr)
                if item.optional_vars is not None:
                    self.env.assign(
                        item.optional_vars,
                        self.env.taint(item.context_expr),
                    )
            self._block(st.body)
        elif isinstance(st, ast.Try):
            self._block(st.body)
            for h in st.handlers:
                self._block(h.body)
            self._block(st.orelse)
            self._block(st.finalbody)
        elif isinstance(st, ast.Assert):
            self._truthiness(st.test)
            self._check_expr(st.test)
        elif isinstance(st, ast.Raise):
            if st.exc is not None:
                self._check_expr(st.exc)

    # -- expressions --------------------------------------------------

    def _truthiness(self, test: ast.expr) -> None:
        if self.env.taint(test):
            self._emit(
                test,
                "truthiness of a device value blocks on the device "
                "(`bool()` forces a host sync)",
            )

    def _check_expr(self, node: ast.expr) -> None:
        if isinstance(node, ast.Lambda):
            return
        if isinstance(node, ast.Call):
            self._check_call(node)
        elif isinstance(node, ast.IfExp):
            self._truthiness(node.test)
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self._check_expr(child)
            elif isinstance(child, ast.keyword):
                self._check_expr(child.value)
            elif isinstance(child, ast.comprehension):
                self._check_expr(child.iter)
                if self.env.taint(child.iter):
                    self._emit(
                        child.iter,
                        "comprehension over a device array — one "
                        "implicit host sync per element",
                    )
                for cond in child.ifs:
                    self._truthiness(cond)
                    self._check_expr(cond)

    def _check_call(self, node: ast.Call) -> None:
        name = self.ctx.resolve(dotted_name(node.func))
        if name is not None:
            seg = last_segment(name)
            if (
                seg in SCALAR_COERCIONS
                and name == seg  # the builtin, not a method
                and node.args
                and self.env.taint(node.args[0])
            ):
                self._emit(
                    node,
                    f"`{seg}()` on a device value forces a host sync",
                )
                return
            if (
                name.startswith("numpy.")
                and seg in NUMPY_MATERIALIZERS
                and node.args
                and self.env.taint(node.args[0])
            ):
                self._emit(
                    node,
                    f"`np.{seg}()` materializes a device array on the host",
                )
                return
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in ("item", "tolist") and self.env.taint(
                node.func.value
            ):
                self._emit(
                    node,
                    f"`.{node.func.attr}()` on a device value forces a "
                    f"host sync",
                )

    def _emit(self, node: ast.AST, message: str) -> None:
        if self.loop_depth > 0:
            message += " (inside a loop: one device round-trip per iteration)"
        self.findings.append(
            self.ctx.finding("JX001", node, self.info.qualname, message)
        )


def check_jx001(
    contexts: list[FileContext], registry: dict
) -> list[Finding]:
    out: list[Finding] = []
    for ctx in contexts:
        for info in ctx.functions:
            if info.is_hot:
                out.extend(_SyncChecker(ctx, info).run())
    return out


# ---------------------------------------------------------------------------
# JX002 — recompile hazards.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class JitEntry:
    """Signature facts for one jitted callable, for call-site checks."""

    name: str
    params: tuple[str, ...]
    static: frozenset[str]


def build_jit_registry(contexts: list[FileContext]) -> dict[str, JitEntry]:
    registry: dict[str, JitEntry] = {}
    for ctx in contexts:
        defs = {
            f.node.name: f
            for f in ctx.functions
            if isinstance(f.node, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for f in ctx.functions:
            if f.jitted and f.static_params:
                registry[f.node.name] = JitEntry(
                    f.node.name, f.params, f.static_params
                )
        for alias, (target, static) in ctx.jit_aliases.items():
            if not static:
                continue
            params = defs[target].params if target in defs else ()
            registry[alias] = JitEntry(alias, params, static)
    return registry


def _is_unhashable_expr(ctx: FileContext, node: ast.expr) -> str | None:
    """A human description of why ``node`` is unhashable, or None."""
    if isinstance(node, (ast.List, ast.ListComp)):
        return "a list"
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return "a dict"
    if isinstance(node, (ast.Set, ast.SetComp)):
        return "a set"
    if isinstance(node, ast.GeneratorExp):
        return "a generator"
    if isinstance(node, ast.Call):
        name = ctx.resolve(dotted_name(node.func)) or ""
        seg = last_segment(name)
        if name == seg and seg in _UNHASHABLE_BUILTINS:
            return f"a {seg}"
        if name.startswith(("numpy.", "jax.numpy.")) and seg in (
            "asarray",
            "array",
            "zeros",
            "ones",
            "arange",
            "empty",
        ):
            return "an array"
    return None


def check_jx002(
    contexts: list[FileContext], registry: dict[str, JitEntry]
) -> list[Finding]:
    out: list[Finding] = []
    for ctx in contexts:
        parents = _parent_map(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.resolve(dotted_name(node.func)) or ""
            if name in ("jax.jit", "jit"):
                out.extend(_jit_call_site(ctx, node, parents))
            elif last_segment(name) in registry and not name.startswith(
                ("jax.", "numpy.")
            ):
                out.extend(
                    _static_args(ctx, node, registry[last_segment(name)])
                )
        # double-jit decorators on one def
        for f in ctx.functions:
            if isinstance(f.node, ast.Lambda):
                continue
            jit_decos = 0
            for dec in f.node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                dname = ctx.resolve(dotted_name(target)) or ""
                if dname in ("jax.jit", "jit"):
                    jit_decos += 1
            if jit_decos > 1:
                out.append(
                    ctx.finding(
                        "JX002",
                        f.node,
                        f.qualname,
                        "stacked jax.jit decorators — the outer jit "
                        "retraces the inner one's dispatch wrapper",
                    )
                )
    return out


def _jit_call_site(
    ctx: FileContext, node: ast.Call, parents: dict[int, ast.AST]
) -> list[Finding]:
    out: list[Finding] = []
    qual = ""
    in_function = in_loop = False
    cur: ast.AST = node
    while True:
        parent = parents.get(id(cur))
        if parent is None:
            break
        if isinstance(parent, (ast.For, ast.While)) and cur in (
            list(parent.body) + list(parent.orelse)
        ):
            in_loop = True
        if isinstance(parent, ast.Lambda):
            in_function = True
        elif isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # decorators evaluate at module scope — only the body (and
            # anything nested under it) counts as "inside" the function
            if cur not in parent.decorator_list:
                in_function = True
        if in_function:
            info = ctx._enclosing(node)
            qual = info.qualname if info else ""
            break
        cur = parent
    if in_loop:
        out.append(
            ctx.finding(
                "JX002",
                node,
                qual,
                "jax.jit constructed inside a loop — a fresh compilation "
                "cache is created (and thrown away) every iteration",
            )
        )
    elif in_function:
        out.append(
            ctx.finding(
                "JX002",
                node,
                qual,
                "jax.jit constructed inside a function body — the "
                "compiled-program cache dies with each call; hoist the "
                "jit to module scope",
            )
        )
    # jit-of-jit
    inner = node.args[0] if node.args else None
    if isinstance(inner, ast.Call):
        inner_name = ctx.resolve(dotted_name(inner.func)) or ""
        if inner_name in ("jax.jit", "jit"):
            out.append(
                ctx.finding(
                    "JX002",
                    node,
                    qual,
                    "jit-of-jit: the outer jit traces the inner jit's "
                    "dispatch machinery",
                )
            )
    elif isinstance(inner, ast.Name):
        # `alias = jax.jit(plain_def)` is the normal module-scope idiom
        # (the assignment is what MAKES the def jitted) — only flag when
        # the target is jit-DECORATED or is itself a jit alias
        target = inner.id
        already = (
            target in ctx.jit_aliases
            and ctx.jit_aliases[target][0] != target
        ) or any(
            f.jit_decorated
            and isinstance(f.node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and f.node.name == target
            for f in ctx.functions
        )
        if already:
            out.append(
                ctx.finding(
                    "JX002",
                    node,
                    qual,
                    f"jit-of-jit: `{target}` is already jit-compiled",
                )
            )
    return out


def _static_args(
    ctx: FileContext, node: ast.Call, entry: JitEntry
) -> list[Finding]:
    out: list[Finding] = []
    info = ctx._enclosing(node)
    qual = info.qualname if info else ""
    bound: list[tuple[str, ast.expr]] = []
    for i, arg in enumerate(node.args):
        if i < len(entry.params):
            bound.append((entry.params[i], arg))
    for kw in node.keywords:
        if kw.arg is not None:
            bound.append((kw.arg, kw.value))
    for pname, expr in bound:
        if pname not in entry.static:
            continue
        why = _is_unhashable_expr(ctx, expr)
        if why:
            out.append(
                ctx.finding(
                    "JX002",
                    expr,
                    qual,
                    f"static argument `{pname}` of `{entry.name}` fed "
                    f"{why} — unhashable statics raise at dispatch, and "
                    f"per-call-varying ones recompile every call",
                )
            )
    return out


# ---------------------------------------------------------------------------
# JX003 — tracer leaks out of traced code.
# ---------------------------------------------------------------------------


def _local_names(fn_node: ast.AST, params: tuple[str, ...]) -> set[str]:
    local = set(params)
    for n in _own_nodes(fn_node):
        if isinstance(n, ast.Assign):
            for t in n.targets:
                local |= _target_names(t)
        elif isinstance(n, (ast.AnnAssign, ast.AugAssign)):
            local |= _target_names(n.target)
        elif isinstance(n, ast.For):
            local |= _target_names(n.target)
        elif isinstance(n, ast.With):
            for item in n.items:
                if item.optional_vars is not None:
                    local |= _target_names(item.optional_vars)
        elif isinstance(n, ast.comprehension):
            local |= _target_names(n.target)
        elif isinstance(n, ast.NamedExpr):
            local |= _target_names(n.target)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            local.add(n.name)
    return local


def _target_names(t: ast.AST) -> set[str]:
    names: set[str] = set()
    if isinstance(t, ast.Name):
        names.add(t.id)
    elif isinstance(t, (ast.Tuple, ast.List)):
        for el in t.elts:
            names |= _target_names(el)
    elif isinstance(t, ast.Starred):
        names |= _target_names(t.value)
    return names


def _attr_base(node: ast.AST) -> str | None:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def check_jx003(
    contexts: list[FileContext], registry: dict
) -> list[Finding]:
    out: list[Finding] = []
    for ctx in contexts:
        for info in ctx.functions:
            if not info.traced:
                continue
            local = _local_names(info.node, info.params)
            for n in _own_nodes(info.node):
                out.extend(_leak_sites(ctx, info, n, local))
    return out


def _leak_sites(
    ctx: FileContext,
    info: FunctionInfo,
    n: ast.AST,
    local: set[str],
) -> list[Finding]:
    out: list[Finding] = []

    def leak(node, what: str):
        out.append(
            ctx.finding(
                "JX003",
                node,
                info.qualname,
                f"{what} from traced code — this runs ONCE at trace "
                f"time with a tracer, not per call",
            )
        )

    if isinstance(n, (ast.Global, ast.Nonlocal)):
        leak(n, f"`{'global' if isinstance(n, ast.Global) else 'nonlocal'}` "
                f"rebind of {', '.join(n.names)}")
        return out
    targets: list[ast.AST] = []
    if isinstance(n, ast.Assign):
        targets = list(n.targets)
    elif isinstance(n, (ast.AugAssign, ast.AnnAssign)):
        targets = [n.target]
    for t in targets:
        if isinstance(t, (ast.Attribute, ast.Subscript)):
            base = _attr_base(t)
            if base == "self":
                leak(t, "write to `self.*`")
            elif base is not None and base not in local:
                leak(t, f"write into closed-over/global `{base}`")
        elif isinstance(t, (ast.Tuple, ast.List)):
            for el in t.elts:
                if isinstance(el, (ast.Attribute, ast.Subscript)):
                    base = _attr_base(el)
                    if base == "self" or (
                        base is not None and base not in local
                    ):
                        leak(el, f"write into `{base}`")
    if (
        isinstance(n, ast.Expr)
        and isinstance(n.value, ast.Call)
        and isinstance(n.value.func, ast.Attribute)
        and n.value.func.attr in _MUTATORS
    ):
        base = _attr_base(n.value.func.value)
        if base == "self" or (base is not None and base not in local):
            leak(
                n.value,
                f"mutating call `.{n.value.func.attr}()` on "
                f"closed-over `{base}`",
            )
    return out


# ---------------------------------------------------------------------------
# JX004 — nondeterminism in traced code.
# ---------------------------------------------------------------------------


def check_jx004(
    contexts: list[FileContext], registry: dict
) -> list[Finding]:
    out: list[Finding] = []
    for ctx in contexts:
        for info in ctx.functions:
            if not info.traced:
                continue
            for n in _own_nodes(info.node):
                if not isinstance(n, ast.Call):
                    continue
                raw = dotted_name(n.func)
                if raw is None:
                    continue
                root = raw.split(".", 1)[0]
                if root not in ctx.aliases:
                    continue  # not an imported module — local name
                name = ctx.resolve(raw) or ""
                hit = name in _NONDET_EXACT or any(
                    name.startswith(p) for p in _NONDET_PREFIXES
                )
                if hit:
                    out.append(
                        ctx.finding(
                            "JX004",
                            n,
                            info.qualname,
                            f"`{raw}()` inside traced code is evaluated "
                            f"once at trace time and baked into the "
                            f"compiled program as a constant",
                        )
                    )
    return out


# ---------------------------------------------------------------------------
# JX005 — pytree registration drift.
# ---------------------------------------------------------------------------


def _class_field_order(cls: ast.ClassDef) -> list[str]:
    fields = []
    for st in cls.body:
        if isinstance(st, ast.AnnAssign) and isinstance(
            st.target, ast.Name
        ):
            fields.append(st.target.id)
    return fields


def _flatten_child_order(fn: ast.AST) -> list[str] | None:
    """Field names in the children tuple of a flatten fn's return, or
    None when the shape is not statically recognizable."""
    if isinstance(fn, ast.Lambda):
        ret = fn.body
        param = fn.args.args[0].arg if fn.args.args else None
    else:
        rets = [
            n
            for n in _own_nodes(fn)
            if isinstance(n, ast.Return) and n.value is not None
        ]
        if len(rets) != 1:
            return None
        ret = rets[0].value
        param = fn.args.args[0].arg if fn.args.args else None
    if not (isinstance(ret, ast.Tuple) and len(ret.elts) == 2):
        return None
    children = ret.elts[0]
    if not isinstance(children, (ast.Tuple, ast.List)):
        return None
    order = []
    for el in children.elts:
        if (
            isinstance(el, ast.Attribute)
            and isinstance(el.value, ast.Name)
            and el.value.id == param
        ):
            order.append(el.attr)
        else:
            return None
    return order


def check_jx005(
    contexts: list[FileContext], registry: dict
) -> list[Finding]:
    out: list[Finding] = []
    for ctx in contexts:
        classes = {
            n.name: n
            for n in ast.walk(ctx.tree)
            if isinstance(n, ast.ClassDef)
        }
        defs = {
            f.node.name: f.node
            for f in ctx.functions
            if isinstance(f.node, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = ctx.resolve(dotted_name(node.func)) or ""
            if last_segment(name) != "register_pytree_node":
                continue
            if len(node.args) < 3:
                continue
            cls_arg, flat_arg, _ = node.args[:3]
            cls = (
                classes.get(cls_arg.id)
                if isinstance(cls_arg, ast.Name)
                else None
            )
            if cls is None:
                continue
            fields = _class_field_order(cls)
            if not fields:
                continue
            flat_fn: ast.AST | None = None
            if isinstance(flat_arg, ast.Lambda):
                flat_fn = flat_arg
            elif isinstance(flat_arg, ast.Name):
                flat_fn = defs.get(flat_arg.id)
            if flat_fn is None:
                continue
            order = _flatten_child_order(flat_fn)
            if order is None:
                continue
            missing = [f for f in fields if f not in order]
            declared_order = [f for f in fields if f in order]
            if missing:
                out.append(
                    ctx.finding(
                        "JX005",
                        node,
                        "",
                        f"flatten for `{cls.name}` drops field(s) "
                        f"{missing} — they silently vanish from every "
                        f"tree_map/jit boundary",
                    )
                )
            elif order != declared_order:
                out.append(
                    ctx.finding(
                        "JX005",
                        node,
                        "",
                        f"flatten children order {order} does not match "
                        f"`{cls.name}` field declaration order "
                        f"{declared_order} — unflatten will scramble "
                        f"fields",
                    )
                )
    return out


ALL_CHECKS = (
    check_jx000,
    check_jx001,
    check_jx002,
    check_jx003,
    check_jx004,
    check_jx005,
)
