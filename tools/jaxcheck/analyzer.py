"""AST machinery: file contexts, import resolution, traced-function
discovery, and the device-taint engine rule JX001 is built on.

Pure stdlib (``ast`` + ``re``): the CI container is 1-core and installs
nothing — parsing ~90k tokens of source takes well under a second.

The central idea is a per-function **device taint** pass: names bound
from ``jax.*`` / ``jax.numpy.*`` calls, from the repo's known
device-producing functions (``config.DEVICE_PRODUCERS``), or from the
parameters of traced code are device values; attribute/subscript/
arithmetic propagate taint; ``numpy.*``, ``float()``, ``.tolist()`` and
friends kill it (the result lives on the host). A host-sync *check*
(``float(x)``, ``np.asarray(x)``, ``x.item()``, truthiness, iteration)
only fires on a tainted expression — which is what keeps JX001 usable
on a codebase with ~500 textual ``float(``/``np.asarray`` sites, almost
all of them host-side and silent.
"""
from __future__ import annotations

import ast
import dataclasses
import fnmatch
import re
from pathlib import Path

from tools.jaxcheck import config
from tools.jaxcheck.base import Finding, normalize_snippet

SUPPRESS_RE = re.compile(
    r"#\s*jaxcheck:\s*(?P<codes>JX\d{3}(?:\s*,\s*JX\d{3})*)\s*"
    r"(?P<ok>ok\b)?\s*(?P<reason>.*)$"
)

# numpy entry points that materialize their argument on the host
NUMPY_MATERIALIZERS = frozenset(
    {"asarray", "array", "asanyarray", "ascontiguousarray"}
)
SCALAR_COERCIONS = frozenset({"float", "int", "bool", "complex"})
# jax.* callables whose RESULT lives on the host (everything else under
# the jax namespace is assumed to produce device values)
JAX_HOST_FNS = frozenset(
    {
        "device_get",
        "devices",
        "local_devices",
        "device_count",
        "local_device_count",
        "default_backend",
        "make_mesh",
        "clear_caches",
        "tree_structure",
    }
)
# builtins that pass their operand's device-ness through to iteration
TAINT_PROPAGATORS = frozenset(
    {"enumerate", "zip", "reversed", "sorted", "iter", "list", "tuple"}
)


# ---------------------------------------------------------------------------
# Dotted-name utilities.
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> str | None:
    """``jax.lax.scan`` for nested Attribute/Name chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def last_segment(dotted: str) -> str:
    return dotted.rsplit(".", 1)[-1]


# ---------------------------------------------------------------------------
# Per-function metadata.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FunctionInfo:
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    qualname: str
    parent: "FunctionInfo | None"
    params: tuple[str, ...]
    jitted: bool = False  # jax.jit decorator or name = jax.jit(fn, ...)
    jit_decorated: bool = False  # @jax.jit on the def itself
    hot_decorated: bool = False  # @hot_path(...) from repro.diag
    traced: bool = False  # jitted, a scan/vmap body, or nested in one
    hot_listed: bool = False  # matches config.HOT_PATHS for this module
    static_params: frozenset[str] = frozenset()

    @property
    def is_hot(self) -> bool:
        return (
            self.hot_listed or self.hot_decorated or self.traced or self.jitted
        )


def _param_names(node: ast.AST) -> tuple[str, ...]:
    a = node.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return tuple(params)


# ---------------------------------------------------------------------------
# File context.
# ---------------------------------------------------------------------------


class FileContext:
    """Everything the rules need to know about one parsed source file."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        # import alias -> absolute module path ("np" -> "numpy",
        # "jnp" -> "jax.numpy", "random" -> "jax.random" when the file
        # does `from jax import random`)
        self.aliases: dict[str, str] = {}
        # suppression directives: line -> (codes, has_ok, reason)
        self.suppress: dict[int, tuple[frozenset[str], bool, str]] = {}
        # function registry (definition order; parents precede children)
        self.functions: list[FunctionInfo] = []
        self._by_node: dict[int, FunctionInfo] = {}
        # module-level `name = jax.jit(fn, static_argnames=...)` aliases:
        # alias -> (target def name, static argnames)
        self.jit_aliases: dict[str, tuple[str, frozenset[str]]] = {}
        self._collect_imports()
        self._collect_suppressions()
        self._collect_functions()
        self._mark_traced()

    # -- collection ---------------------------------------------------

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0]
                    )
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}"
                    )

    def _collect_suppressions(self) -> None:
        for i, line in enumerate(self.lines, start=1):
            if "jaxcheck" not in line:
                continue
            hash_pos = line.find("#")
            if hash_pos < 0:
                continue
            m = SUPPRESS_RE.search(line, hash_pos)
            if not m:
                continue
            codes = frozenset(
                c.strip() for c in m.group("codes").split(",")
            )
            ok = bool(m.group("ok"))
            reason = m.group("reason").strip()
            self.suppress[i] = (codes, ok, reason)

    def resolve(self, dotted: str | None) -> str | None:
        """Rewrite the root of a dotted name through the import table:
        ``np.asarray`` -> ``numpy.asarray``, ``jnp.sum`` ->
        ``jax.numpy.sum``. Unknown roots pass through unchanged."""
        if dotted is None:
            return None
        root, _, rest = dotted.partition(".")
        base = self.aliases.get(root, root)
        return f"{base}.{rest}" if rest else base

    def _decorator_info(self, node) -> tuple[bool, bool, frozenset[str]]:
        """(jitted, hot_decorated, static_params) from a def's decorators."""
        jitted = hot = False
        static: set[str] = set()
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = self.resolve(dotted_name(target)) or ""
            if last_segment(name) == "hot_path" or name.endswith(
                "diag.hot_path"
            ):
                hot = True
            if name in ("jax.jit", "jit"):
                jitted = True
                if isinstance(dec, ast.Call):
                    static |= self._static_names(dec)
            # functools.partial(jax.jit, static_argnames=...)
            if (
                isinstance(dec, ast.Call)
                and last_segment(name) == "partial"
                and dec.args
            ):
                inner = self.resolve(dotted_name(dec.args[0])) or ""
                if inner in ("jax.jit", "jit"):
                    jitted = True
                    static |= self._static_names(dec)
        return jitted, hot, frozenset(static)

    @staticmethod
    def _static_names(call: ast.Call) -> set[str]:
        names: set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(
                        el.value, str
                    ):
                        names.add(el.value)
        return names

    def _hot_patterns(self) -> tuple[str, ...]:
        for mod_pat, fn_pats in config.HOT_PATHS.items():
            if fnmatch.fnmatch(self.rel, mod_pat):
                return fn_pats
        return ()

    def _collect_functions(self) -> None:
        hot_pats = self._hot_patterns()

        def visit(node: ast.AST, parent: FunctionInfo | None, prefix: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    qual = f"{prefix}{child.name}" if prefix else child.name
                    jitted, hot, static = self._decorator_info(child)
                    info = FunctionInfo(
                        node=child,
                        qualname=qual,
                        parent=parent,
                        params=_param_names(child),
                        jitted=jitted,
                        jit_decorated=jitted,
                        hot_decorated=hot,
                        static_params=static,
                        hot_listed=any(
                            fnmatch.fnmatch(qual, p) for p in hot_pats
                        ),
                    )
                    self.functions.append(info)
                    self._by_node[id(child)] = info
                    visit(child, info, f"{qual}.")
                elif isinstance(child, ast.Lambda):
                    qual = f"{prefix}<lambda>"
                    info = FunctionInfo(
                        node=child,
                        qualname=qual,
                        parent=parent,
                        params=_param_names(child),
                    )
                    self.functions.append(info)
                    self._by_node[id(child)] = info
                    visit(child, info, f"{qual}.")
                elif isinstance(child, ast.ClassDef):
                    visit(child, parent, f"{prefix}{child.name}.")
                else:
                    visit(child, parent, prefix)

        visit(self.tree, None, "")
        # module-level `name = jax.jit(fn, ...)` marks fn jitted and
        # registers the alias for the static-argument rule
        by_name = {
            f.node.name: f
            for f in self.functions
            if isinstance(f.node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and f.parent is None
        }
        for stmt in self.tree.body:
            if not (
                isinstance(stmt, ast.Assign)
                and len(stmt.targets) == 1
                and isinstance(stmt.targets[0], ast.Name)
                and isinstance(stmt.value, ast.Call)
            ):
                continue
            fn_name = self.resolve(dotted_name(stmt.value.func)) or ""
            if fn_name not in ("jax.jit", "jit"):
                continue
            static = frozenset(self._static_names(stmt.value))
            target_def = (
                stmt.value.args[0].id
                if stmt.value.args
                and isinstance(stmt.value.args[0], ast.Name)
                else None
            )
            self.jit_aliases[stmt.targets[0].id] = (
                target_def or "",
                static,
            )
            if target_def and target_def in by_name:
                info = by_name[target_def]
                info.jitted = True
                info.static_params = info.static_params | static

    def _mark_traced(self) -> None:
        # seed: jit-decorated defs trace their bodies
        for f in self.functions:
            if f.jitted:
                f.traced = True
        # defs / lambdas passed to scan/vmap/while_loop/... are traced
        by_name_scope: dict[tuple[int, str], FunctionInfo] = {}
        for f in self.functions:
            if isinstance(f.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = id(f.parent.node) if f.parent else 0
                by_name_scope[(scope, f.node.name)] = f
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name = self.resolve(dotted_name(node.func)) or ""
            if last_segment(name) not in config.TRACE_CONSUMERS:
                continue
            enclosing = self._enclosing(node)
            scope = id(enclosing.node) if enclosing else 0
            for arg in list(node.args) + [k.value for k in node.keywords]:
                if isinstance(arg, ast.Lambda):
                    info = self._by_node.get(id(arg))
                    if info:
                        info.traced = True
                elif isinstance(arg, ast.Name):
                    info = by_name_scope.get((scope, arg.id))
                    if info:
                        info.traced = True
        # nested defs inside traced functions are traced; iterate to a
        # fixpoint (definition order puts parents first, so one extra
        # sweep suffices in practice)
        changed = True
        while changed:
            changed = False
            for f in self.functions:
                if not f.traced and f.parent is not None and f.parent.traced:
                    f.traced = True
                    changed = True

    def _enclosing(self, node: ast.AST) -> FunctionInfo | None:
        """Innermost function containing ``node`` (by position)."""
        best: FunctionInfo | None = None
        for f in self.functions:
            fn = f.node
            if (
                hasattr(node, "lineno")
                and fn.body[0].lineno
                <= node.lineno
                <= (fn.end_lineno or fn.body[-1].end_lineno)
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
                else False
            ):
                if best is None or (
                    fn.lineno >= best.node.lineno
                ):
                    best = f
        return best

    # -- finding helpers ---------------------------------------------

    def finding(
        self, rule: str, node: ast.AST, qualname: str, message: str
    ) -> Finding:
        line = getattr(node, "lineno", 1)
        snippet = (
            self.lines[line - 1] if 0 < line <= len(self.lines) else ""
        )
        return Finding(
            rule=rule,
            path=self.rel,
            line=line,
            qualname=qualname,
            message=message,
            snippet=normalize_snippet(snippet),
        )

    def is_suppressed(self, f: Finding) -> bool:
        """Same-line directive, or one on an immediately preceding
        comment-only line. Malformed directives never suppress (rule
        JX000 reports them separately)."""
        for line in (f.line, f.line - 1):
            entry = self.suppress.get(line)
            if entry is None:
                continue
            if line == f.line - 1:
                stripped = self.lines[line - 1].lstrip()
                if not stripped.startswith("#"):
                    continue
            codes, ok, reason = entry
            if f.rule in codes and ok and reason:
                return True
        return False


# ---------------------------------------------------------------------------
# Device-taint engine (rule JX001's core).
# ---------------------------------------------------------------------------


class TaintEnv:
    """Flow-sensitive-enough name taint for one function body."""

    def __init__(self, ctx: FileContext, info: FunctionInfo):
        self.ctx = ctx
        self.info = info
        self.tainted: set[str] = set()
        if info.traced:
            # every traced param is a tracer — syncing one raises at
            # trace time anyway; flag it statically
            self.tainted |= set(info.params) - set(info.static_params)
            self.tainted.discard("self")
        elif info.hot_decorated and not isinstance(info.node, ast.Lambda):
            # hot-path functions take mixed host/device params; only
            # Array-annotated ones are declared device values
            a = info.node.args
            for arg in a.posonlyargs + a.args + a.kwonlyargs:
                if arg.annotation is not None and self._is_array_ann(
                    arg.annotation
                ):
                    self.tainted.add(arg.arg)

    def _is_array_ann(self, ann: ast.AST) -> bool:
        for n in ast.walk(ann):
            name = self.ctx.resolve(dotted_name(n))
            if name in (
                "jax.Array",
                "jax.numpy.ndarray",
                "jaxtyping.Array",
            ):
                return True
        return False

    # -- expression taint ---------------------------------------------

    def taint(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in config.HOST_ATTRS:
                return False
            return self.taint(node.value)
        if isinstance(node, ast.Subscript):
            return self.taint(node.value)
        if isinstance(node, ast.Call):
            return self._call_taint(node)
        if isinstance(node, ast.BinOp):
            return self.taint(node.left) or self.taint(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.taint(node.operand)
        if isinstance(node, ast.Compare):
            # `x is None` yields a python bool even for tracers
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return self.taint(node.left) or any(
                self.taint(c) for c in node.comparators
            )
        if isinstance(node, ast.BoolOp):
            return any(self.taint(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return self.taint(node.body) or self.taint(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.taint(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.taint(node.value)
        if isinstance(node, ast.NamedExpr):
            return self.taint(node.value)
        return False

    def _call_taint(self, node: ast.Call) -> bool:
        name = self.ctx.resolve(dotted_name(node.func))
        if name is not None:
            seg = last_segment(name)
            if name.startswith("jax.") or name == "jax":
                return seg not in JAX_HOST_FNS
            if name.startswith("numpy.") or name.startswith("builtins."):
                return False
            if seg in config.HOST_SINKS or seg in SCALAR_COERCIONS:
                return False
            if seg in TAINT_PROPAGATORS:
                return any(self.taint(a) for a in node.args)
            if any(
                fnmatch.fnmatch(seg, p) for p in config.DEVICE_PRODUCERS
            ):
                return True
        # method call on a tainted receiver stays on device (x.mean(),
        # sols.pi.sum(), carry._replace(...))
        if isinstance(node.func, ast.Attribute):
            if node.func.attr in ("tolist", "item"):
                return False
            return self.taint(node.func.value)
        return False

    # -- assignment updates -------------------------------------------

    def assign(self, target: ast.AST, value_tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if value_tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self.assign(el, value_tainted)
        elif isinstance(target, ast.Starred):
            self.assign(target.value, value_tainted)
        # Attribute / Subscript targets: no name to (un)taint


def iter_source_files(paths: list[Path], repo_root: Path) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            files.append(p)
        elif p.is_dir():
            files.extend(
                f
                for f in sorted(p.rglob("*.py"))
                if not any(part.startswith(".") for part in f.parts)
            )
    return files


def build_contexts(
    paths: list[Path], repo_root: Path
) -> tuple[list[FileContext], list[Finding]]:
    """Parse every file; unparsable files become findings, not crashes."""
    contexts: list[FileContext] = []
    errors: list[Finding] = []
    for f in iter_source_files(paths, repo_root):
        try:
            rel = f.resolve().relative_to(repo_root.resolve()).as_posix()
        except ValueError:
            rel = f.as_posix()
        try:
            contexts.append(FileContext(f, rel, f.read_text()))
        except SyntaxError as e:
            errors.append(
                Finding(
                    rule="JX000",
                    path=rel,
                    line=e.lineno or 1,
                    qualname="",
                    message=f"file does not parse: {e.msg}",
                    snippet="",
                )
            )
    return contexts, errors
