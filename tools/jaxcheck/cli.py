"""Command line: ``python -m tools.jaxcheck src/repro [--baseline F]``.

Exit codes: 0 clean (or fully baselined), 1 new findings, 2 usage /
baseline-format errors. Each new finding prints with its rule's fix
hint; stale baseline entries warn but do not fail (they indicate the
baseline can shrink — shrink it in the same PR that fixed the code).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.jaxcheck import baseline as baseline_mod
from tools.jaxcheck.analyzer import build_contexts
from tools.jaxcheck.base import RULES, Finding
from tools.jaxcheck.rules import ALL_CHECKS, build_jit_registry


def analyze_paths(
    paths: list[Path], repo_root: Path | None = None
) -> list[Finding]:
    """Run every rule over ``paths``; suppressed findings are dropped,
    sorted by (path, line, rule)."""
    root = repo_root or Path.cwd()
    contexts, errors = build_contexts(paths, root)
    registry = build_jit_registry(contexts)
    by_rel = {ctx.rel: ctx for ctx in contexts}
    findings: list[Finding] = list(errors)
    for check in ALL_CHECKS:
        findings.extend(check(contexts, registry))
    kept = [
        f
        for f in findings
        if f.rule == "JX000"
        or f.path not in by_rel
        or not by_rel[f.path].is_suppressed(f)
    ]
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.jaxcheck",
        description="repo-specific JAX static analysis (JX001-JX005)",
    )
    parser.add_argument("paths", nargs="+", type=Path)
    parser.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="accepted-findings file (tab-separated, reasons mandatory)",
    )
    parser.add_argument(
        "--write-baseline",
        type=Path,
        default=None,
        help="write current findings as a baseline skeleton (reasons "
        "filled with TODO; edit before committing) and exit 0",
    )
    args = parser.parse_args(argv)

    for p in args.paths:
        if not p.exists():
            print(f"jaxcheck: no such path: {p}", file=sys.stderr)
            return 2

    findings = analyze_paths(args.paths)

    if args.write_baseline is not None:
        lines = [
            "# jaxcheck baseline: rule<TAB>path::qualname<TAB>snippet"
            "<TAB>reason",
            "# Reasons are mandatory. Shrink this file whenever you fix "
            "a finding.",
        ]
        lines += [
            baseline_mod.format_baseline_line(
                f, "TODO: justify or fix"
            )
            for f in findings
        ]
        args.write_baseline.write_text("\n".join(lines) + "\n")
        print(
            f"jaxcheck: wrote {len(findings)} finding(s) to "
            f"{args.write_baseline}"
        )
        return 0

    accepted = None
    if args.baseline is not None:
        try:
            accepted = baseline_mod.parse_baseline(args.baseline)
        except (OSError, baseline_mod.BaselineError) as e:
            print(f"jaxcheck: baseline error: {e}", file=sys.stderr)
            return 2

    if accepted is not None:
        new, stale = baseline_mod.diff_against_baseline(
            findings, accepted
        )
    else:
        new, stale = findings, []

    for key in stale:
        rule, path, qualname, snippet = key
        print(
            f"jaxcheck: stale baseline entry (fixed? shrink the "
            f"baseline): {rule} {path}::{qualname} | {snippet}"
        )

    if not new:
        n = len(findings)
        suffix = (
            f" ({n} baselined finding(s))" if accepted is not None and n
            else ""
        )
        print(f"jaxcheck: clean{suffix}")
        return 0

    hinted: set[str] = set()
    for f in new:
        print(f.format())
        if f.rule not in hinted:
            rule = RULES.get(f.rule)
            if rule is not None:
                print(f"    hint: {rule.hint}")
            hinted.add(f.rule)
    print(
        f"jaxcheck: {len(new)} new finding(s). Fix them, suppress "
        f"inline (`# jaxcheck: JX00N ok <reason>`), or add a "
        f"reasoned baseline entry."
    )
    return 1
