"""Docs consistency checker (CI `docs` job; stdlib only).

Fails (exit 1) when any markdown file in ``docs/`` or the top-level
``README.md`` / ``ROADMAP.md`` contains:

* a relative markdown link ``[text](path)`` whose target does not exist
  (anchors are stripped; http(s)/mailto links are ignored), or
* a backtick-quoted repo path reference (``src/...``, ``benchmarks/...``,
  ``docs/...``, ``tests/...``, ``examples/...``, ``tools/...``) that does
  not exist on disk, or
* a ``benchmarks/results/*.csv`` reference that NO benchmark can write.
  The results directory is generated (gitignored), so existence on disk
  proves nothing in CI; instead the referenced file name must match an
  ``emit(rows, "<name>")`` literal somewhere in ``benchmarks/*.py``
  (f-string placeholders become wildcards, e.g. the scenario suite's
  ``scenario_{...}`` covers ``scenario_node_failure.csv``).

Keeps the "documentation maps back to the code" guarantee honest: renames
and refactors that orphan a doc reference break CI instead of rotting.
"""
from __future__ import annotations

import fnmatch
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/repro/core/jlcm.py`, ``benchmarks/scenario_suite.py`` etc.
PATH_REF = re.compile(
    r"`{1,2}((?:src|benchmarks|docs|tests|examples|tools)/[A-Za-z0-9_./-]+)`{1,2}"
)
RESULTS_REF = re.compile(r"^benchmarks/results/([A-Za-z0-9_.*{}-]+\.csv)$")
# emit(rows, "fig8_convergence") / emit(rows, f"scenario_{...}"); the name
# runs lazily to the quote that closes the call, so f-string placeholders
# may contain nested quotes (e.g. .replace('-', '_'))
EMIT_CALL = re.compile(r"""emit\(\s*[^,]+,\s*(f?)(["'])(.+?)\2\s*\)""")


def emittable_csv_patterns() -> list[str]:
    """fnmatch patterns for every CSV name some benchmark can write."""
    patterns = []
    for py in sorted((REPO / "benchmarks").glob("*.py")):
        for is_f, _quote, name in EMIT_CALL.findall(py.read_text()):
            if is_f:  # f-string: any {placeholder} matches anything
                name = re.sub(r"\{[^}]*\}", "*", name)
            patterns.append(f"{name}.csv")
    return patterns


def doc_files() -> list[Path]:
    files = [REPO / "README.md", REPO / "ROADMAP.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(md: Path, csv_patterns: list[str]) -> list[str]:
    errors = []
    text = md.read_text()
    for link in LINK.findall(text):
        if link.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = link.split("#")[0]
        if not target:
            continue
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {link}")
    for ref in PATH_REF.findall(text):
        ref = ref.rstrip(".")  # tolerate trailing sentence dots
        m = RESULTS_REF.match(ref)
        if m:
            # generated CSVs: validate against what benchmarks can emit,
            # not the (gitignored) disk state
            name = m.group(1)
            if not any(fnmatch.fnmatch(name, p) for p in csv_patterns):
                errors.append(
                    f"{md.relative_to(REPO)}: results CSV no benchmark "
                    f"writes -> {ref}"
                )
            continue
        if not (REPO / ref).exists():
            errors.append(f"{md.relative_to(REPO)}: dead path reference -> {ref}")
    return errors


def main() -> int:
    errors = []
    files = doc_files()
    csv_patterns = emittable_csv_patterns()
    for md in files:
        errors.extend(check_file(md, csv_patterns))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"check_docs: {len(files)} files, {len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
