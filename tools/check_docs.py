"""Docs consistency checker (CI `docs` job; stdlib only).

Fails (exit 1) when any markdown file in ``docs/`` or the top-level
``README.md`` / ``ROADMAP.md`` contains:

* a relative markdown link ``[text](path)`` whose target does not exist
  (anchors are stripped; http(s)/mailto links are ignored), or
* a backtick-quoted repo path reference (``src/...``, ``benchmarks/...``,
  ``docs/...``, ``tests/...``, ``examples/...``, ``tools/...``) that does
  not exist on disk.

Keeps the "documentation maps back to the code" guarantee honest: renames
and refactors that orphan a doc reference break CI instead of rotting.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `src/repro/core/jlcm.py`, ``benchmarks/scenario_suite.py`` etc.
PATH_REF = re.compile(
    r"`{1,2}((?:src|benchmarks|docs|tests|examples|tools)/[A-Za-z0-9_./-]+)`{1,2}"
)


def doc_files() -> list[Path]:
    files = [REPO / "README.md", REPO / "ROADMAP.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text()
    for link in LINK.findall(text):
        if link.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = link.split("#")[0]
        if not target:
            continue
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{md.relative_to(REPO)}: broken link -> {link}")
    for ref in PATH_REF.findall(text):
        target = REPO / ref.rstrip(".")  # tolerate trailing sentence dots
        if not target.exists():
            errors.append(f"{md.relative_to(REPO)}: dead path reference -> {ref}")
    return errors


def main() -> int:
    errors = []
    files = doc_files()
    for md in files:
        errors.extend(check_file(md))
    for e in errors:
        print(f"ERROR: {e}")
    print(f"check_docs: {len(files)} files, {len(errors)} errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
